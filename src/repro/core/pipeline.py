"""Pass-manager API — the compiler's mid-section as first-class values.

The Fig. 8 pipeline used to be a hardcoded call chain in
``compiler.run_passes`` gated by ``CompileOptions`` booleans.  This module
makes it an MLIR-style pipeline instead:

* a :class:`Pass` protocol — ``name``, ``run(prog, ctx) -> prog``, plus
  optional dependency metadata (``requires``/``establishes``/``invalidates``)
  and per-run ``stats``;
* a module-level **registry** (:func:`register_pass`) holding every builtin
  pass from :mod:`repro.core.passes` and any user plugin registered through
  ``revet.register_pass`` — both slot into the same namespace;
* a :class:`PassManager` that executes a pipeline parsed from a textual spec
  (``"lower-memory-sugar,insert-frees,...,infer-widths"``) with three
  instrumentation hooks: ``print_ir_after`` (textual IR via
  ``ir.Program.as_text()``), ``verify_each`` (the structural
  :mod:`repro.core.verifier`), and ``time_each`` (per-pass wall time + IR
  node-count deltas collected into a :class:`PipelineReport`).

``CompileOptions`` is rebuilt *on top of* this: its booleans synthesize a
pipeline spec (``CompileOptions.pipeline_spec()``), and the spec — not the
flag tuple — keys the front-end compile cache.
"""
from __future__ import annotations

import copy as _copy
import inspect
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Protocol, runtime_checkable

from . import ir, passes
from .verifier import _SUGAR, verify_program

__all__ = [
    "Pass", "PassContext", "PassError", "PassManager", "PassRecord",
    "PipelineError", "PipelineReport", "available_passes", "get_pass",
    "initial_invariants", "parse_pipeline", "register_pass",
    "resolve_requirements",
]

PassError = passes.PassError


class PipelineError(ValueError):
    """Bad pipeline spec: unknown pass, duplicate registration, or a pass
    whose required invariants no earlier pass establishes."""


# ---------------------------------------------------------------------------
# Pass protocol + context
# ---------------------------------------------------------------------------

@dataclass
class PassContext:
    """Mutable state threaded through one pipeline run."""
    options: Any = None                    # the driving CompileOptions, if any
    widths: dict[str, int] = field(default_factory=dict)   # infer-widths out
    established: set[str] = field(default_factory=set)     # invariants held
    stats: dict[str, int] = field(default_factory=dict)    # current pass's

    def stat(self, key: str, value: int = 1) -> None:
        """Accumulate a counter into the running pass's record."""
        self.stats[key] = self.stats.get(key, 0) + value


@runtime_checkable
class Pass(Protocol):
    """What the :class:`PassManager` executes.  ``run`` may mutate ``prog``
    in place and return it (the builtin style) or return a replacement."""
    name: str
    requires: tuple[str, ...]      # invariants that must hold on entry
    establishes: tuple[str, ...]   # invariants guaranteed after this pass
    invalidates: tuple[str, ...]   # invariants this pass destroys

    def run(self, prog: ir.Program, ctx: PassContext) -> ir.Program: ...


@dataclass(frozen=True)
class _RegisteredPass:
    name: str
    fn: Callable
    requires: tuple[str, ...] = ()
    establishes: tuple[str, ...] = ()
    invalidates: tuple[str, ...] = ()
    wants_ctx: bool = False

    def run(self, prog: ir.Program, ctx: PassContext) -> ir.Program:
        out = self.fn(prog, ctx) if self.wants_ctx else self.fn(prog)
        return prog if out is None else out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

PASS_REGISTRY: dict[str, _RegisteredPass] = {}


def register_pass(name: str, *, requires: tuple[str, ...] = (),
                  establishes: tuple[str, ...] = (),
                  invalidates: tuple[str, ...] = (),
                  replace: bool = False) -> Callable:
    """Decorator registering a pass function under ``name``.

    The function takes ``(prog)`` or ``(prog, ctx)`` — arity is detected —
    and returns the (possibly in-place mutated) program, or ``None`` to mean
    "mutated in place".  User plugins use the same decorator via
    ``revet.register_pass`` and become addressable from any pipeline spec::

        @revet.register_pass("constant-fold")
        def constant_fold(prog, ctx):
            ...
    """
    def deco(fn: Callable) -> Callable:
        if name in PASS_REGISTRY and not replace:
            raise PipelineError(
                f"pass {name!r} is already registered "
                "(pass replace=True to override)")
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
        PASS_REGISTRY[name] = _RegisteredPass(
            name, fn, tuple(requires), tuple(establishes),
            tuple(invalidates), wants_ctx=len(params) >= 2)
        return fn
    return deco


def get_pass(name: str) -> _RegisteredPass:
    try:
        return PASS_REGISTRY[name]
    except KeyError:
        raise PipelineError(
            f"unknown pass {name!r}; registered: {available_passes()}"
        ) from None


def available_passes() -> list[str]:
    return sorted(PASS_REGISTRY)


def parse_pipeline(spec: "str | list[str] | tuple[str, ...]"
                   ) -> list[_RegisteredPass]:
    """Parse a textual spec (comma-separated pass names, whitespace ignored)
    or a name sequence into registered passes."""
    if isinstance(spec, str):
        names = [n.strip() for n in spec.split(",")]
    else:
        names = [str(n).strip() for n in spec]
    return [get_pass(n) for n in names if n]


def normalize_spec(spec: "str | list[str] | tuple[str, ...]") -> str:
    """Canonical spec string (also validates every pass name)."""
    return ",".join(p.name for p in parse_pipeline(spec))


def resolve_requirements(names: "list[str] | tuple[str, ...]") -> list[str]:
    """Prepend providers for any invariant the named passes require but no
    earlier pass establishes — ``["hoist-allocators"]`` becomes
    ``["lower-memory-sugar", "insert-frees", "hoist-allocators"]``."""
    providers = {inv: p.name for p in PASS_REGISTRY.values()
                 for inv in p.establishes}
    out: list[str] = []
    held: set[str] = set()

    def add(name: str) -> None:
        p = get_pass(name)
        for inv in p.requires:
            if inv not in held:
                if inv not in providers:
                    raise PipelineError(
                        f"pass {name!r} requires {inv!r}, which no "
                        "registered pass establishes")
                add(providers[inv])
        if name not in out:
            out.append(name)
            held.update(p.establishes)

    for n in names:
        add(n)
    return out


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------

@dataclass
class PassRecord:
    """One executed pass: wall time + IR node-count deltas + pass counters."""
    name: str
    wall_s: float
    stmts_before: int
    stmts_after: int
    exprs_before: int
    exprs_after: int
    stats: dict[str, int] = field(default_factory=dict)

    @property
    def stmt_delta(self) -> int:
        return self.stmts_after - self.stmts_before

    @property
    def expr_delta(self) -> int:
        return self.exprs_after - self.exprs_before


@dataclass
class PipelineReport:
    """What one :meth:`PassManager.run` did, pass by pass."""
    spec: str
    records: list[PassRecord] = field(default_factory=list)
    total_wall_s: float = 0.0
    verified: bool = False
    widths: dict[str, int] = field(default_factory=dict)
    ir_texts: list[tuple[str, str]] = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "spec": self.spec,
            "total_wall_s": self.total_wall_s,
            "verified": self.verified,
            "passes": [{
                "name": r.name, "wall_s": r.wall_s,
                "stmts_before": r.stmts_before, "stmts_after": r.stmts_after,
                "exprs_before": r.exprs_before, "exprs_after": r.exprs_after,
                "stats": dict(r.stats),
            } for r in self.records],
        }

    def __str__(self) -> str:
        head = f"pipeline: {self.spec}"
        if not self.records:
            return head
        w = max(len(r.name) for r in self.records)
        lines = [head]
        for r in self.records:
            extra = "".join(f"  {k}={v}" for k, v in sorted(r.stats.items()))
            lines.append(
                f"  {r.name:<{w}}  {r.wall_s * 1e3:8.2f} ms  "
                f"stmts {r.stmts_before:>5} -> {r.stmts_after:<5} "
                f"exprs {r.exprs_before:>5} -> {r.exprs_after:<5}{extra}")
        lines.append(f"  {'total':<{w}}  {self.total_wall_s * 1e3:8.2f} ms"
                     + ("  (verified)" if self.verified else ""))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The manager
# ---------------------------------------------------------------------------

def initial_invariants(prog: ir.Program) -> set[str]:
    """Invariants already true of the *input* program, so custom pipelines
    over pre-lowered IR don't have to re-run the providing passes."""
    held = {"no-sugar", "frees-inserted"}
    decls: set[str] = set()
    freed: set[str] = set()
    if prog.main:
        for s in ir.walk(prog.main.body):
            if isinstance(s, _SUGAR):
                held.discard("no-sugar")
            elif isinstance(s, ir.SRAMDecl):
                decls.add(s.var)
            elif isinstance(s, ir.SRAMFree):
                freed.add(s.var)
    if decls - freed or ("no-sugar" not in held):
        held.discard("frees-inserted")
    return held


class PassManager:
    """Execute a pipeline over an ``ir.Program`` with instrumentation.

    Parameters
    ----------
    spec:
        Textual pipeline (``"a,b,c"``) or sequence of registered pass names.
    verify_each:
        Run :func:`repro.core.verifier.verify_program` on the input and after
        every pass (raises :class:`VerificationError` on the first breach).
    time_each:
        Collect per-pass wall time and node-count deltas (cheap; on by
        default — node counts are two tree walks).
    print_ir_after:
        ``True`` to print the IR after every pass to stdout, or a callable
        ``(pass_name, text) -> None``; either way the texts are also kept on
        ``PipelineReport.ir_texts``.
    """

    def __init__(self, spec: "str | list[str] | tuple[str, ...]", *,
                 verify_each: bool = False, time_each: bool = True,
                 print_ir_after: "bool | Callable[[str, str], None]" = False):
        self.passes = parse_pipeline(spec)
        self.spec = ",".join(p.name for p in self.passes)
        self.verify_each = verify_each
        self.time_each = time_each
        self.print_ir_after = print_ir_after

    # -- execution ----------------------------------------------------------
    def run(self, prog: ir.Program, options: Any = None, *,
            copy: bool = True) -> tuple[ir.Program, PipelineReport]:
        if copy:
            prog = _copy.deepcopy(prog)
        ctx = PassContext(options=options,
                          established=initial_invariants(prog))
        report = PipelineReport(spec=self.spec)
        t_start = time.perf_counter()
        if self.verify_each:
            verify_program(prog, ctx.established, stage="input")
            report.verified = True
        for p in self.passes:
            missing = set(p.requires) - ctx.established
            if missing:
                raise PipelineError(
                    f"pass {p.name!r} requires invariant(s) "
                    f"{sorted(missing)} not established by this pipeline "
                    f"({self.spec!r}); hint: "
                    f"{','.join(resolve_requirements([p.name]))}")
            before = prog.node_count() if self.time_each else {}
            ctx.stats = {}
            t0 = time.perf_counter()
            prog = p.run(prog, ctx)
            wall = time.perf_counter() - t0
            ctx.established -= set(p.invalidates)
            ctx.established |= set(p.establishes)
            if self.time_each:
                after = prog.node_count()
                report.records.append(PassRecord(
                    p.name, wall, before["stmts"], after["stmts"],
                    before["exprs"], after["exprs"], dict(ctx.stats)))
            if self.print_ir_after:
                text = prog.as_text()
                report.ir_texts.append((p.name, text))
                if callable(self.print_ir_after):
                    self.print_ir_after(p.name, text)
                else:
                    print(f"// ----- IR after {p.name} -----")
                    print(text)
            if self.verify_each:
                verify_program(prog, ctx.established, stage=p.name)
        report.total_wall_s = time.perf_counter() - t_start
        report.widths = dict(ctx.widths)
        return prog, report


# ---------------------------------------------------------------------------
# Builtin passes — the Fig. 8 mid-section, one registry entry each
# ---------------------------------------------------------------------------

register_pass("lower-memory-sugar", establishes=("no-sugar",))(
    passes.lower_memory_sugar)
register_pass("insert-frees", requires=("no-sugar",),
              establishes=("frees-inserted",))(passes.insert_frees)
register_pass("eliminate-hierarchy",
              requires=("no-sugar", "frees-inserted"))(
    passes.eliminate_hierarchy)
register_pass("if-to-select", requires=("no-sugar",))(passes.if_to_select)
register_pass("fuse-allocations", requires=("no-sugar",))(
    passes.fuse_allocations)
register_pass("hoist-allocators", requires=("no-sugar", "frees-inserted"))(
    passes.hoist_allocators)


@register_pass("infer-widths", requires=("no-sugar",))
def _infer_widths(prog: ir.Program, ctx: PassContext) -> ir.Program:
    """Sub-word width analysis (§V-B(d)) — writes ``ctx.widths``; the IR is
    untouched.  Present in a pipeline iff ``subword_packing`` is on."""
    ctx.widths = passes.infer_widths(prog)
    ctx.stat("packed_vars", sum(1 for w in ctx.widths.values() if w < 32))
    return prog


# the in-tree plugin example: an optimization pass registered through the
# exact same decorator user code reaches via ``revet.register_pass``
from . import constfold as _constfold  # noqa: E402,F401  (registers itself)

# the placement stage's marker pass ("place") — the actual placement runs
# post-lowering in the compiler driver; see core/place.py
from . import place as _place  # noqa: E402,F401  (registers itself)

"""Structural IR/DFG verifier — the invariants ``lowering.py`` silently
assumes, made explicit and checkable between passes.

``verify_program`` checks the structured IR:

* **declarations** — every DRAM/pool reference resolves; SRAM buffer names
  are globally unique (lowering builds its buffer->pool map on that);
* **defined-before-use** — every variable a statement reads is definitely
  assigned on *all* paths reaching it (lowering sizes link payloads from
  liveness; a maybe-undefined live-in becomes a register the VM never wrote);
* **frees match allocations** — every ``SRAMFree`` names an in-scope buffer
  of the same pool; once the ``frees-inserted`` invariant is established,
  every allocation also has a matching free;
* **yield discipline** — ``Yield`` only inside a *reducing* ``foreach`` and
  only at its thread-tail depth (``if`` nesting is fine; crossing a
  ``while``/``fork``/inner ``foreach`` is the atomics territory of Fig. 9);
* **fork tail position** — ``Fork`` must be the last statement of a thread
  body, fork body, or while body (lowering wires children into the loop
  backedge there and nowhere else);
* **sugar absence** — once ``no-sugar`` is established, no view/iterator
  statement may remain.

``verify_dfg`` checks the lowered graph: every link has exactly one producer
output and one consumer head (the single-producer/single-consumer link
precondition), barrier-depth bookkeeping at multi-input heads (zip/merge
inputs at equal depth; a loop backedge exactly one deeper than its forward
input), and that every register a context's body or outputs read is actually
produced by its head or an earlier body op.
"""
from __future__ import annotations

from . import ir
from .dfg import (DFG, CounterHead, ForwardMergeHead, FwdBwdMergeHead,
                  SingleHead, SourceHead, ZipHead, head_links)
from .ir import (Exit, Foreach, Fork, If, ItAdvance, ItDeref, ItWrite,
                 ReadItDecl, Replicate, SRAMDecl, SRAMFree, ViewDecl,
                 ViewLoad, ViewStore, While, WriteItDecl, Yield)
from .liveness import stmt_uses_defs

_SUGAR = (ViewDecl, ViewLoad, ViewStore, ReadItDecl, ItDeref, ItAdvance,
          WriteItDecl, ItWrite)

# block kinds whose tail is a thread tail (a Fork may sit there)
_FORKABLE = ("main", "foreach", "fork", "while-body")


class VerificationError(Exception):
    """A structural invariant the lowering relies on does not hold."""


def _fail(stage: str, msg: str) -> None:
    where = f" [after {stage}]" if stage else ""
    raise VerificationError(msg + where)


def verify_program(prog: ir.Program, established: set[str] | frozenset = (),
                   stage: str = "") -> None:
    """Raise :class:`VerificationError` if ``prog`` violates an invariant.

    ``established`` names pipeline invariants already provided by earlier
    passes (``"no-sugar"``, ``"frees-inserted"``); the conditional checks
    only run once their providing pass has run.  ``stage`` tags error
    messages with the pass that just ran.
    """
    established = set(established)
    if prog.main is None:
        return
    v = _Verifier(prog, established, stage)
    v.check_decls()
    v.check_block(prog.main.body, defined=set(prog.main.params),
                  block_kind="main", reduce_frame=None)
    if "frees-inserted" in established:
        v.check_frees_complete()


class _Verifier:
    def __init__(self, prog: ir.Program, established: set[str], stage: str):
        self.prog = prog
        self.established = established
        self.stage = stage
        self.buf_pools: dict[str, str] = {}

    def fail(self, msg: str) -> None:
        _fail(self.stage, msg)

    # -- declarations -------------------------------------------------------
    def check_decls(self) -> None:
        for s in ir.walk(self.prog.main.body):
            if isinstance(s, SRAMDecl):
                if s.var in self.buf_pools:
                    self.fail(f"SRAM buffer '{s.var}' declared twice "
                              "(lowering requires globally unique names)")
                self.buf_pools[s.var] = s.pool
                if s.pool not in self.prog.pools:
                    self.fail(f"SRAMDecl '{s.var}' uses undeclared pool "
                              f"'{s.pool}'")
                elif s.size > self.prog.pools[s.pool].buf_words:
                    self.fail(
                        f"SRAM buffer '{s.var}' ({s.size} words) exceeds "
                        f"pool '{s.pool}' buffer size "
                        f"({self.prog.pools[s.pool].buf_words} words) — "
                        "accesses would alias the neighboring buffer")
            elif isinstance(s, SRAMFree):
                if s.pool not in self.prog.pools:
                    self.fail(f"SRAMFree '{s.var}' names undeclared pool "
                              f"'{s.pool}'")
            arr = getattr(s, "arr", None)
            if arr is not None and arr not in self.prog.dram:
                self.fail(f"{type(s).__name__} references undeclared DRAM "
                          f"array '{arr}'")
            if isinstance(s, _SUGAR) and "no-sugar" in self.established:
                self.fail(f"{type(s).__name__} survived sugar lowering")
            if isinstance(s, SRAMFree):
                pool = self.buf_pools.get(s.var)
                if pool is not None and pool != s.pool:
                    self.fail(f"SRAMFree '{s.var}' pool '{s.pool}' does not "
                              f"match its declaration pool '{pool}'")
            if isinstance(s, Foreach) and s.eliminate_hierarchy \
                    and s.reduce_op is not None:
                self.fail("pragma(eliminate_hierarchy) foreach cannot also "
                          "reduce — use atomics (Fig. 9)")

    # -- frees --------------------------------------------------------------
    def check_frees_complete(self) -> None:
        freed = {s.var for s in ir.walk(self.prog.main.body)
                 if isinstance(s, SRAMFree)}
        for buf in self.buf_pools:
            if buf not in freed:
                self.fail(f"SRAM buffer '{buf}' is allocated but never "
                          "freed (frees-inserted discipline)")

    # -- definite assignment + structure ------------------------------------
    def check_block(self, stmts: list[ir.Stmt], defined: set[str],
                    block_kind: str, reduce_frame: str | None
                    ) -> set[str] | None:
        """Verify one statement list.  Returns the definitely-defined set at
        the block's end, or ``None`` if the block always exits the thread."""
        for i, s in enumerate(stmts):
            uses, defs = stmt_uses_defs(s)
            missing = sorted(u for u in uses if u not in defined)
            if missing:
                self.fail(f"{type(s).__name__} reads undefined variable(s) "
                          f"{missing}")
            if isinstance(s, Exit):
                return None                      # rest of block unreachable
            if isinstance(s, If):
                dt = self.check_block(s.then, set(defined), "if",
                                      reduce_frame)
                de = self.check_block(s.els, set(defined), "if",
                                      reduce_frame)
                if dt is None and de is None:
                    return None
                defined = (dt if de is None else
                           de if dt is None else dt & de)
            elif isinstance(s, While):
                # a while raises the barrier depth: yields inside cannot
                # reach the enclosing reduction network (Fig. 9 discipline)
                dh = self.check_block(s.header, set(defined), "while-header",
                                      None)
                if dh is None:
                    self.fail("while header always exits")
                cond_missing = sorted(u for u in ir.expr_vars(s.cond)
                                      if u not in dh)
                if cond_missing:
                    self.fail("while condition reads undefined variable(s) "
                              f"{cond_missing}")
                self.check_block(s.body, set(dh), "while-body", None)
                defined = dh                     # header runs at least once
            elif isinstance(s, Foreach):
                frame = s.ivar if s.reduce_op is not None else None
                self.check_block(s.body, set(defined) | {s.ivar}, "foreach",
                                 frame)
                defined |= defs                  # reduce_var, if any
            elif isinstance(s, Fork):
                if i != len(stmts) - 1:
                    self.fail("fork must be the last statement of its block")
                if block_kind not in _FORKABLE:
                    self.fail(f"fork in a {block_kind} block is not a thread "
                              "tail (lowering cannot wire its continuation)")
                self.check_block(s.body, set(defined) | {s.ivar}, "fork",
                                 None)
            elif isinstance(s, Replicate):
                d = self.check_block(s.body, set(defined), "replicate",
                                     reduce_frame)
                if d is None:
                    return None
                defined = d
            elif isinstance(s, Yield):
                if reduce_frame is None:
                    self.fail("yield outside a reducing foreach (or across a "
                              "while/fork boundary — use atomic_add, Fig. 9)")
            else:
                defined |= defs
        return defined


# ---------------------------------------------------------------------------
# DFG-level checks (run after lowering)
# ---------------------------------------------------------------------------

def verify_dfg(g: DFG, stage: str = "lowering") -> None:
    """Single producer/consumer per link, barrier-depth bookkeeping, and
    register availability inside each context."""
    g.validate()     # no dangling producers/consumers, output arities
    producers: dict[int, int] = {}
    consumers: dict[int, int] = {}
    for c in g.contexts.values():
        for o in c.outs:
            producers[o.link] = producers.get(o.link, 0) + 1
        for lid in head_links(c.head):
            consumers[lid] = consumers.get(lid, 0) + 1
    for lid, link in g.links.items():
        if producers.get(lid, 0) > 1:
            _fail(stage, f"link {lid} ({link.vars}) has "
                         f"{producers[lid]} producers (must be single)")
        if consumers.get(lid, 0) != 1:
            _fail(stage, f"link {lid} ({link.vars}) has "
                         f"{consumers.get(lid, 0)} consumers (must be 1)")

    for c in g.contexts.values():
        h = c.head
        if isinstance(h, (ZipHead, ForwardMergeHead)):
            depths = {g.links[l].depth for l in head_links(h)}
            if len(depths) > 1:
                _fail(stage, f"ctx {c.name}: merged links at unequal "
                             f"barrier depths {sorted(depths)}")
        elif isinstance(h, FwdBwdMergeHead):
            df, db = g.links[h.fwd].depth, g.links[h.back].depth
            if db != df + 1:
                _fail(stage, f"ctx {c.name}: backedge depth {db} != "
                             f"forward depth {df} + 1")
        _check_context_regs(g, c, stage)


def _check_context_regs(g: DFG, c, stage: str) -> None:
    h = c.head
    if isinstance(h, SourceHead):
        avail = set(getattr(g, "source_vars", ()))
    else:
        avail = {v for lid in head_links(h) for v in g.links[lid].vars}
    if isinstance(h, CounterHead):
        avail.add(h.ivar)
        for r in (h.lo, h.hi, h.step):
            if r not in avail:
                _fail(stage, f"ctx {c.name}: counter bound '{r}' not on the "
                             "incoming link")
    for op in c.body:
        for r in op.srcs:
            if r not in avail:
                _fail(stage, f"ctx {c.name}: body op '{op.op}' reads "
                             f"unavailable register '{r}'")
        if op.pred is not None and op.pred not in avail:
            _fail(stage, f"ctx {c.name}: predicate '{op.pred}' unavailable")
        if op.dst is not None:
            avail.add(op.dst)
    for o in c.outs:
        for r in o.values:
            if r not in avail:
                _fail(stage, f"ctx {c.name}: output carries unavailable "
                             f"register '{r}'")
        if o.pred is not None and o.pred not in avail:
            _fail(stage, f"ctx {c.name}: filter predicate '{o.pred}' "
                         "unavailable")

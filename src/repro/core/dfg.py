"""Dataflow graph (DFG) — the compiler's target, mirroring §III-C / §V-C.

A program lowers to a graph of *contexts*. Each context is structured exactly
like the paper's virtual compute unit:

* **pipeline head** — merging / expansion / synchronization logic
  (:class:`SingleHead`, :class:`ZipHead`, :class:`ForwardMergeHead`,
  :class:`FwdBwdMergeHead`, :class:`CounterHead`, :class:`SourceHead`);
* **pipeline body** — a register program of element-wise operations,
  including memory operations (scratchpad / DRAM / atomics) chained by
  program order (the void-token discipline of §III-B(a) is implicit in the
  body's sequential op list and is made explicit when splitting);
* **pipeline tail** — outputs: unconditional, filtered (conditional branch),
  reducing (foreach exit), or barrier-lowering (loop exit / flatten).

Links carry SLTF streams (``core/sltf.py``). ``Link.depth`` records static
barrier nesting; ``Link.kind`` records the vector/scalar mapping decision of
the link-analysis pass (§V-D(a)).

Machine-model note (documented deviation, see DESIGN.md): our loop header
emits group barriers *only* on the exit edge and the reserved Ω1 wave markers
*only* on the backedge/body path. The paper routes the raised barrier through
the body; both disciplines are equivalent (the header is the single
synchronization point of a natural loop) and ours avoids a barrier round-trip
per group.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Links
# ---------------------------------------------------------------------------

@dataclass
class Link:
    id: int
    vars: tuple[str, ...]          # payload variable names (ordered)
    depth: int                     # static barrier nesting depth
    kind: str = "vector"           # "vector" | "scalar"  (§V-D(a))
    src: Optional[int] = None      # producer context id
    dst: Optional[int] = None      # consumer context id

    @property
    def nvars(self) -> int:
        return len(self.vars)


# ---------------------------------------------------------------------------
# Heads
# ---------------------------------------------------------------------------

@dataclass
class SourceHead:
    """Program entry: the launcher injects main()'s parameter tuple."""


@dataclass
class SingleHead:
    link: int


@dataclass
class ZipHead:
    """Wait-for-all element-wise alignment of parallel tensors (§III-C:
    "wait for all inputs to be available for element-wise operations").
    All links must carry identical barrier structure; payloads concatenate."""
    links: list[int]


@dataclass
class ForwardMergeHead:
    """Interleaves two forward branches; stalls at barriers until both sides
    reach an equal barrier, then emits one (§III-B(c))."""
    a: int
    b: int


@dataclass
class FwdBwdMergeHead:
    """Natural-loop header (§III-B(d)). Protocol state lives in the VM:
    forward tokens flow until a barrier arrives; then the loop recirculates
    via ``back`` until an empty wave (two consecutive Ω1) is observed, after
    which the pending forward barrier is released on the exit path."""
    fwd: int
    back: int


@dataclass
class CounterHead:
    """Expansion (§III-B(b)): each input token becomes a group of tokens with
    an appended counter value.

    ``add_level=True``  -> foreach: output barriers are input+1, each group
                           closed by (possibly implied) Ω1.
    ``add_level=False`` -> fork: expansion/flattening pair fused — children
                           appear at the *same* level, no group barriers.
    ``lo/hi/step`` name payload vars of the incoming link.
    """
    link: int
    lo: str
    hi: str
    step: str
    ivar: str
    add_level: bool = True


# ---------------------------------------------------------------------------
# Body ops (element-wise register program)
# ---------------------------------------------------------------------------

@dataclass
class BodyOp:
    """One pipeline-stage instruction. ``op`` is an IR binop/unop name or:
    const, mov, select, sram_load, sram_store, dram_load, dram_store,
    atomic_add, alloc, free. ``dst``/``srcs`` are register names (strings ==
    variable names; lowering keeps IR var names for debuggability)."""
    op: str
    dst: Optional[str]
    srcs: tuple[str, ...] = ()
    imm: Optional[int] = None
    space: Optional[str] = None    # memory space: SRAM pool or DRAM array name
    width: int = 32                # sub-word annotation (packing pass)
    pred: Optional[str] = None     # predicate register (predicated stores)


# ---------------------------------------------------------------------------
# Outputs (pipeline tail)
# ---------------------------------------------------------------------------

@dataclass
class Output:
    """One tail output.

    kind:
      "pass"    — every thread is sent.
      "filter"  — only threads with ``pred`` != 0 are sent (§III-B(c)).
      "reduce"  — associative reduction of the innermost dimension; emits one
                  token per Ω1 group carrying the accumulator; lowers barriers
                  by one (§III-B(b)).
      "discard" — tail of an Exit path: barriers pass, data is dropped.
    ``lower_barrier`` additionally applies `flatten` (Ω1 dropped, Ωn->Ωn-1) —
    used on loop-exit edges and yield relays.
    """
    link: int
    kind: str = "pass"
    values: tuple[str, ...] = ()
    pred: Optional[str] = None
    reduce_op: Optional[str] = None
    reduce_init: int = 0
    lower_barrier: bool = False


# ---------------------------------------------------------------------------
# Context & graph
# ---------------------------------------------------------------------------

Head = object


@dataclass
class Context:
    id: int
    name: str
    head: Head
    body: list[BodyOp] = field(default_factory=list)
    outs: list[Output] = field(default_factory=list)
    replicate_group: Optional[int] = None   # id shared by replicate copies
    replicate_copy: Optional[int] = None    # which copy this context is in
    nest_depth: int = 0                     # loop-nesting (placement priority)


@dataclass
class DFG:
    name: str = "prog"
    contexts: dict[int, Context] = field(default_factory=dict)
    links: dict[int, Link] = field(default_factory=dict)
    entry: Optional[int] = None             # context with SourceHead
    result_link: Optional[int] = None       # main()'s completion link
    dram: dict = field(default_factory=dict)      # name -> ir.DRAMArray
    pools: dict = field(default_factory=dict)     # name -> ir.SRAMPool
    _next_ctx: int = 0
    _next_link: int = 0

    # -- construction helpers -------------------------------------------------
    def new_link(self, vars: tuple[str, ...], depth: int) -> Link:
        l = Link(self._next_link, tuple(vars), depth)
        self.links[l.id] = l
        self._next_link += 1
        return l

    def new_context(self, name: str, head: Head, nest_depth: int = 0) -> Context:
        c = Context(self._next_ctx, name, head, nest_depth=nest_depth)
        self.contexts[c.id] = c
        self._next_ctx += 1
        for lid in head_links(head):
            self.links[lid].dst = c.id
        return c

    def attach_out(self, ctx: Context, out: Output) -> None:
        ctx.outs.append(out)
        self.links[out.link].src = ctx.id

    # -- queries ----------------------------------------------------------------
    def in_links(self, ctx: Context) -> list[int]:
        return head_links(ctx.head)

    def out_links(self, ctx: Context) -> list[int]:
        return [o.link for o in ctx.outs]

    def validate(self) -> None:
        for l in self.links.values():
            if l.dst is None:
                raise ValueError(f"link {l.id} ({l.vars}) has no consumer")
            if l.src is None and not isinstance(
                    self.contexts[l.dst].head, SourceHead):
                raise ValueError(f"link {l.id} ({l.vars}) has no producer")
        for c in self.contexts.values():
            for o in c.outs:
                link = self.links[o.link]
                if o.kind in ("pass", "filter") and not o.lower_barrier \
                        and len(o.values) != link.nvars:
                    raise ValueError(
                        f"ctx {c.name}: output arity {len(o.values)} != "
                        f"link {link.id} arity {link.nvars}")

    def context_depths(self) -> dict[int, int]:
        """Longest acyclic path length (in contexts) from the entry;
        loop-header backedges ignored.  Shared by the machine model's
        retiming estimates (``machine.map_graph``) and the placement
        stage's section ordering (``place.place_graph``)."""
        depth: dict[int, int] = {}
        order = list(self.contexts)
        for _ in range(len(order)):
            changed = False
            for cid in order:
                c = self.contexts[cid]
                d = 0
                for lid in head_links(c.head):
                    src = self.links[lid].src
                    if src is None:
                        continue
                    if isinstance(c.head, FwdBwdMergeHead) and \
                            lid == c.head.back:
                        continue   # ignore the backedge
                    d = max(d, depth.get(src, 0) + 1)
                if depth.get(cid) != d:
                    depth[cid] = d
                    changed = True
            if not changed:
                break
        return depth

    def topo_order(self) -> list[int]:
        """Context ids sorted by acyclic depth (ties broken by id) — the
        dataflow-forward order placement packs sections in."""
        depth = self.context_depths()
        return sorted(self.contexts, key=lambda cid: (depth.get(cid, 0), cid))

    def stats(self) -> dict:
        return {
            "contexts": len(self.contexts),
            "links": len(self.links),
            "body_ops": sum(len(c.body) for c in self.contexts.values()),
            "vector_links": sum(1 for l in self.links.values()
                                if l.kind == "vector"),
            "scalar_links": sum(1 for l in self.links.values()
                                if l.kind == "scalar"),
        }


def head_links(head: Head) -> list[int]:
    if isinstance(head, SourceHead):
        return []
    if isinstance(head, SingleHead):
        return [head.link]
    if isinstance(head, ZipHead):
        return list(head.links)
    if isinstance(head, ForwardMergeHead):
        return [head.a, head.b]
    if isinstance(head, FwdBwdMergeHead):
        return [head.fwd, head.back]
    if isinstance(head, CounterHead):
        return [head.link]
    raise TypeError(f"unknown head {head}")

"""Deterministic synthetic data pipeline, sharded by host.

Every (step, host) pair maps to a disjoint, reproducible token block via a
counter-based PRNG (no state to checkpoint beyond the step counter — restart
-safe by construction, which is what the fault-tolerance path relies on).
Sequences are "packed documents": geometric-length runs with EOS separators,
so loss masks and document boundaries behave like a real LM mixture.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

EOS = 0


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    mean_doc_len: int = 512
    seed: int = 1234


class Pipeline:
    """Stateless-per-step pipeline: ``batch(step)`` is pure."""

    def __init__(self, cfg: DataConfig, host_id: int = 0):
        self.cfg = cfg
        self.host_id = host_id
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.host_id))

    def local_batch_np(self, step: int) -> np.ndarray:
        rng = self._rng(step)
        b, s, v = self.local_batch, self.cfg.seq_len, self.cfg.vocab
        toks = rng.integers(1, v, size=(b, s), dtype=np.int32)
        # plant EOS boundaries (packed documents)
        n_docs = max(1, s // self.cfg.mean_doc_len)
        for row in range(b):
            cuts = rng.integers(1, s, size=n_docs)
            toks[row, cuts] = EOS
        return toks

    def batch(self, step: int) -> dict[str, jnp.ndarray]:
        return {"tokens": jnp.asarray(self.local_batch_np(step))}

    def global_batch_np(self, step: int) -> np.ndarray:
        """All hosts' shards concatenated (single-process testing)."""
        rows = []
        for h in range(self.cfg.n_hosts):
            p = Pipeline(self.cfg, host_id=h)
            rows.append(p.local_batch_np(step))
        return np.concatenate(rows, axis=0)

"""Sharding rules: logical parameter axes + batch/cache layouts -> mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The pod axis extends data parallelism across pods (gradients all-reduce over
pod×data; the dry-run proves the pod axis shards).

All rules are **divisibility-aware**: a dimension is only sharded when its
size divides the mesh axis; otherwise it falls back (KV caches fall back from
heads->model to seq->model; everything else falls back to replication).
This is what lets one rule set serve 10 architectures × 4 shapes, including
global_batch=1 long-context cells.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from ..models.params import P

# logical param axis -> mesh axis (tensor/expert parallelism)
PARAM_RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "ff": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "expert_ff": "data",     # 2nd axis for MoE expert weights (FSDP-style)
    "inner": "model",
    "embed": None,
    "embed2": None,
    "layers": None,
    "sublayers": None,
    "state": None,
    "conv": None,
}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _div(n: int, mesh: Mesh, axes) -> bool:
    return n % axis_size(mesh, axes) == 0


def param_pspec(p: P, mesh: Mesh) -> PS:
    """PartitionSpec for one parameter, dropping non-divisible shardings."""
    spec = []
    for dim, ax in zip(p.shape, p.axes):
        rule = PARAM_RULES.get(ax) if ax else None
        spec.append(rule if rule and _div(dim, mesh, rule) else None)
    return PS(*spec)


def param_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, param_pspec(p, mesh)),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def zero_pspec(p: P, mesh: Mesh) -> PS:
    """ZeRO: optimizer moments additionally shard their largest still-
    replicated dim over the data axes (state is only needed shard-wise at
    the update)."""
    base = list(param_pspec(p, mesh))
    dax = data_axes(mesh)
    if not dax:
        return PS(*base)
    used = {a for s in base if s
            for a in ((s,) if isinstance(s, str) else s)}
    if used & set(dax):
        return PS(*base)   # param already shards over the data axes
    # choose the largest dim that is currently unsharded and divisible
    cands = [(dim, i) for i, (dim, s) in enumerate(zip(p.shape, base))
             if s is None and _div(dim, mesh, dax)]
    if cands:
        _, i = max(cands)
        base[i] = dax if len(dax) > 1 else dax[0]
    return PS(*base)


def zero_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, zero_pspec(p, mesh)),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


# -- activations / batches -----------------------------------------------------

def batch_pspec(shape: tuple[int, ...], mesh: Mesh,
                seq_dim: Optional[int] = None,
                seq_shard: bool = False) -> PS:
    """Batch dim 0 over (pod, data) when divisible; optional sequence
    sharding over model (sequence parallelism) for long-context cells."""
    dax = data_axes(mesh)
    spec: list = [None] * len(shape)
    if dax and shape[0] % axis_size(mesh, dax) == 0 and shape[0] > 1:
        spec[0] = dax if len(dax) > 1 else dax[0]
    if seq_shard and seq_dim is not None and \
            shape[seq_dim] % mesh.shape["model"] == 0:
        spec[seq_dim] = "model"
    return PS(*spec)


def batch_shardings(batch_specs: dict, mesh: Mesh, seq_shard: bool = False):
    out = {}
    for k, sd in batch_specs.items():
        seq_dim = 1 if len(sd.shape) >= 2 else None
        out[k] = NamedSharding(mesh, batch_pspec(sd.shape, mesh,
                                                 seq_dim=seq_dim,
                                                 seq_shard=seq_shard))
    return out


# -- KV / recurrent caches -------------------------------------------------------

# name -> (batch_dim, head_dim, seq_dim, width_dim) — None if absent
_CACHE_LAYOUT = {
    "k": (1, 2, 3, None), "v": (1, 2, 3, None),
    "ks": (1, 2, 3, None), "vs": (1, 2, 3, None),
    "xk": (1, 2, 3, None), "xv": (1, 2, 3, None),
    "attn_k": (1, 2, 3, None), "attn_v": (1, 2, 3, None),
    "h": (1, None, None, 2),           # ssm state [L, B, Di, N]
    "conv": (1, None, None, 3),        # ssm conv  [L, B, K-1, Di]
    "rec_h": (2, None, None, 3),       # [G, R, B, W]
    "rec_conv": (2, None, None, 4),    # [G, R, B, K-1, W]
    "tail_h": (1, None, None, 2),
    "tail_conv": (1, None, None, 3),
}


def cache_pspec(name: str, shape: tuple[int, ...], mesh: Mesh) -> PS:
    bdim, hdim, sdim, wdim = _CACHE_LAYOUT[name]
    dax = data_axes(mesh)
    spec: list = [None] * len(shape)
    if dax and shape[bdim] % axis_size(mesh, dax) == 0 and shape[bdim] > 1:
        spec[bdim] = dax if len(dax) > 1 else dax[0]
    m = mesh.shape["model"]
    if hdim is not None and shape[hdim] % m == 0:
        spec[hdim] = "model"
    elif sdim is not None and shape[sdim] % m == 0:
        spec[sdim] = "model"               # fallback: shard the KV sequence
    elif wdim is not None and shape[wdim] % m == 0:
        spec[wdim] = "model"               # recurrent widths
    return PS(*spec)


def cache_shardings(cache_specs: dict, mesh: Mesh):
    return {k: NamedSharding(mesh, cache_pspec(k, v.shape, mesh))
            for k, v in cache_specs.items()}


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PS())


# -- activation sharding hints (set by the dry-run / launchers) -----------------
#
# Models are mesh-agnostic; when a launcher installs an active mesh, the
# layers can request activation reshardings with plain axis tuples. Outside a
# launcher (unit tests, host runs) these are no-ops.

_ACT_MESH: Mesh | None = None


def set_act_mesh(mesh: Optional[Mesh]) -> None:
    global _ACT_MESH
    _ACT_MESH = mesh


def act_mesh_axis(name: str) -> int:
    """Size of a mesh axis under the active mesh (1 if none)."""
    if _ACT_MESH is None or name not in _ACT_MESH.shape:
        return 1
    return int(_ACT_MESH.shape[name])


def act_hint(x, *axes):
    """with_sharding_constraint under the active mesh; each entry of ``axes``
    is a mesh-axis name, a tuple of names, or None. Non-divisible entries are
    dropped; no-op without an active mesh."""
    if _ACT_MESH is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        names = tuple(a for a in ((ax,) if isinstance(ax, str) else ax)
                      if a in _ACT_MESH.shape)
        if names and dim % axis_size(_ACT_MESH, names) == 0 and dim > 1:
            spec.append(names if len(names) > 1 else names[0])
        else:
            spec.append(None)
    import jax
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, PS(*spec)))

"""Fault tolerance & fleet hygiene for 1000+ node runs.

* :class:`Supervisor` — checkpoint/restart driver: runs the step function,
  checkpoints every N steps, and on failure (hardware fault, preemption)
  restores the latest checkpoint and replays. The data pipeline is
  counter-based (data/pipeline.py), so restart is exactly-once without
  dataloader state.
* :class:`StragglerMonitor` — per-step wall-time tracker with robust z-score
  outlier detection; at scale this drives hot-swap decisions (here: logged +
  surfaced in metrics, and unit-tested on synthetic timings).
* :class:`PreemptionGuard` — cooperative preemption: a flag file (stand-in
  for the TPU maintenance-event signal) triggers checkpoint-and-exit at the
  next step boundary.
* :class:`LaunchSupervisor` — the :class:`Supervisor`'s restart discipline
  applied to *serving launches* (serve/async_engine.py): a launch is
  stateless-in/stateless-out, so a failed attempt is replayed verbatim
  (exactly-once without checkpoints), wall times feed a
  :class:`StragglerMonitor`, and repeated failures of the preferred
  (resident) mode flip the engine into degraded windowed execution.
"""
from __future__ import annotations

import os
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from ..checkpoint import ckpt


class SimulatedFault(RuntimeError):
    """Raised by tests / chaos hooks to emulate a node failure."""


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold: float = 4.0         # robust z-score (MAD-based)
    times: list[float] = field(default_factory=list)
    flagged: list[tuple[int, float]] = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < 8:
            return False
        med = statistics.median(self.times)
        mad = statistics.median(abs(t - med) for t in self.times) or 1e-9
        z = 0.6745 * (seconds - med) / mad
        if z > self.threshold:
            self.flagged.append((step, seconds))
            return True
        return False


@dataclass
class PreemptionGuard:
    flag_path: str

    def requested(self) -> bool:
        return os.path.exists(self.flag_path)


@dataclass
class LaunchSupervisor:
    """Retry/degrade driver for serving launches.

    ``run(attempt_fn, mode)`` calls ``attempt_fn(attempt)`` up to
    ``max_retries + 1`` times, re-raising the last error when every attempt
    fails.  Launches are pure functions of their request batch, so a replay
    returns bit-identical results — the engine's retry contract.

    Every failure (and every completed launch that overruns ``timeout_s``)
    is a *strike* against its execution mode; once the ``"resident"`` mode
    collects ``degrade_after`` strikes, :attr:`degraded` latches True and
    the engine falls back to windowed execution (a completed-but-slow
    launch still returns its result — the strike only steers future mode
    choice).  Launch walls feed the :class:`StragglerMonitor`, surfacing
    tail launches in :attr:`log` exactly like training steps.
    """
    max_retries: int = 2
    degrade_after: int = 2
    timeout_s: Optional[float] = None
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    launches: int = 0
    retries: int = 0
    failures: int = 0
    mode_failures: dict = field(default_factory=dict)
    degraded: bool = False
    log: list[str] = field(default_factory=list)

    def strike(self, mode: str, reason: str) -> bool:
        """Record one failure/overrun against ``mode``; returns True when
        this strike latched degraded mode."""
        n = self.mode_failures[mode] = self.mode_failures.get(mode, 0) + 1
        self.log.append(f"{mode} strike {n}: {reason}")
        if mode == "resident" and not self.degraded \
                and n >= self.degrade_after:
            self.degraded = True
            self.log.append(
                f"degraded: resident -> windowed after {n} strikes")
            return True
        return False

    def run(self, attempt_fn: Callable, mode: str = "windowed"):
        last = None
        for attempt in range(self.max_retries + 1):
            try:
                t0 = time.monotonic()
                out = attempt_fn(attempt)
                dt = time.monotonic() - t0
            except Exception as e:          # noqa: BLE001 — replay anything
                last = e
                self.failures += 1
                self.strike(mode, f"attempt {attempt}: {e!r}")
                if attempt == self.max_retries:
                    raise
                self.retries += 1
                continue
            self.launches += 1
            if self.monitor.record(self.launches, dt):
                self.log.append(f"straggler launch {self.launches}: "
                                f"{dt:.3f}s")
            if self.timeout_s is not None and dt > self.timeout_s:
                self.strike(mode, f"launch overran timeout "
                                  f"({dt:.3f}s > {self.timeout_s:.3f}s)")
            return out
        raise last                           # pragma: no cover — unreachable


@dataclass
class Supervisor:
    """Checkpoint/restart training driver.

    ``state`` is any pytree (params + optimizer + anything else);
    ``step_fn(state, step) -> state`` runs one step and may raise.
    """
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 10
    keep: int = 3
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    preemption: Optional[PreemptionGuard] = None
    restarts: int = 0
    log: list[str] = field(default_factory=list)

    def run(self, state, step_fn: Callable, n_steps: int,
            start_step: int = 0, shardings=None):
        step = start_step
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is not None and latest > step:
            state = ckpt.restore(self.ckpt_dir, latest, state, shardings)
            step = latest
            self.log.append(f"resumed from step {latest}")
        while step < n_steps:
            try:
                t0 = time.monotonic()
                state = step_fn(state, step)
                dt = time.monotonic() - t0
                step += 1
                if self.monitor.record(step, dt):
                    self.log.append(f"straggler at step {step}: {dt:.3f}s")
                if step % self.ckpt_every == 0 or step == n_steps:
                    ckpt.save(self.ckpt_dir, step, state, keep=self.keep)
                if self.preemption and self.preemption.requested():
                    ckpt.save(self.ckpt_dir, step, state, keep=self.keep)
                    self.log.append(f"preempted at step {step}")
                    return state, step
            except SimulatedFault as e:
                self.restarts += 1
                self.log.append(f"fault at step {step}: {e}; restart "
                                f"{self.restarts}/{self.max_restarts}")
                if self.restarts > self.max_restarts:
                    raise
                latest = ckpt.latest_step(self.ckpt_dir)
                if latest is None:
                    step = start_step
                    continue
                state = ckpt.restore(self.ckpt_dir, latest, state, shardings)
                step = latest
        return state, step

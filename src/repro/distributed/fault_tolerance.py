"""Fault tolerance & fleet hygiene for 1000+ node runs.

* :class:`Supervisor` — checkpoint/restart driver: runs the step function,
  checkpoints every N steps, and on failure (hardware fault, preemption)
  restores the latest checkpoint and replays. The data pipeline is
  counter-based (data/pipeline.py), so restart is exactly-once without
  dataloader state.
* :class:`StragglerMonitor` — per-step wall-time tracker with robust z-score
  outlier detection; at scale this drives hot-swap decisions (here: logged +
  surfaced in metrics, and unit-tested on synthetic timings).
* :class:`PreemptionGuard` — cooperative preemption: a flag file (stand-in
  for the TPU maintenance-event signal) triggers checkpoint-and-exit at the
  next step boundary.
"""
from __future__ import annotations

import os
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from ..checkpoint import ckpt


class SimulatedFault(RuntimeError):
    """Raised by tests / chaos hooks to emulate a node failure."""


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold: float = 4.0         # robust z-score (MAD-based)
    times: list[float] = field(default_factory=list)
    flagged: list[tuple[int, float]] = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < 8:
            return False
        med = statistics.median(self.times)
        mad = statistics.median(abs(t - med) for t in self.times) or 1e-9
        z = 0.6745 * (seconds - med) / mad
        if z > self.threshold:
            self.flagged.append((step, seconds))
            return True
        return False


@dataclass
class PreemptionGuard:
    flag_path: str

    def requested(self) -> bool:
        return os.path.exists(self.flag_path)


@dataclass
class Supervisor:
    """Checkpoint/restart training driver.

    ``state`` is any pytree (params + optimizer + anything else);
    ``step_fn(state, step) -> state`` runs one step and may raise.
    """
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 10
    keep: int = 3
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    preemption: Optional[PreemptionGuard] = None
    restarts: int = 0
    log: list[str] = field(default_factory=list)

    def run(self, state, step_fn: Callable, n_steps: int,
            start_step: int = 0, shardings=None):
        step = start_step
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is not None and latest > step:
            state = ckpt.restore(self.ckpt_dir, latest, state, shardings)
            step = latest
            self.log.append(f"resumed from step {latest}")
        while step < n_steps:
            try:
                t0 = time.monotonic()
                state = step_fn(state, step)
                dt = time.monotonic() - t0
                step += 1
                if self.monitor.record(step, dt):
                    self.log.append(f"straggler at step {step}: {dt:.3f}s")
                if step % self.ckpt_every == 0 or step == n_steps:
                    ckpt.save(self.ckpt_dir, step, state, keep=self.keep)
                if self.preemption and self.preemption.requested():
                    ckpt.save(self.ckpt_dir, step, state, keep=self.keep)
                    self.log.append(f"preempted at step {step}")
                    return state, step
            except SimulatedFault as e:
                self.restarts += 1
                self.log.append(f"fault at step {step}: {e}; restart "
                                f"{self.restarts}/{self.max_restarts}")
                if self.restarts > self.max_restarts:
                    raise
                latest = ckpt.latest_step(self.ckpt_dir)
                if latest is None:
                    step = start_step
                    continue
                state = ckpt.restore(self.ckpt_dir, latest, state, shardings)
                step = latest
        return state, step

"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick for 1000+ node scale).

int8 quantization with error feedback: each step, the residual from the
previous step's quantization is added back before quantizing, so the scheme
is unbiased over time (EF-SGD). The compressed representation (int8 payload +
f32 scale) is what would transit the pod-interconnect — a 4× reduction in
gradient bytes on the slowest links; the decompress happens after the
all-reduce. The train loop enables this with ``--grad-compression int8``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def compress(g, err):
    """Returns ((q_int8, scale), new_error)."""
    gf = g.astype(F32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    return (q, scale), gf - deq


def decompress(q, scale):
    return q.astype(F32) * scale


def compress_tree(grads, err_state):
    qs, new_err = [], []
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    for g, e in zip(flat_g, flat_e):
        (q, s), ne = compress(g, e)
        qs.append((q, s))
        new_err.append(ne)
    return jax.tree.unflatten(tdef, [q for q in qs]), \
        jax.tree.unflatten(tdef, new_err)


def roundtrip_tree(grads, err_state):
    """compress+decompress in one jit (what the wire would carry); returns
    (dequantized grads, new error state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        (q, s), ne = compress(g, e)
        outs.append(decompress(q, s).astype(g.dtype))
        errs.append(ne)
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, errs)

"""Sharded AdamW with warmup+cosine schedule and global-norm clipping.

State layout is ZeRO-style: first/second moments are f32 and take the
``zero_shardings`` layout (sharded over the data axes on top of TP), so
optimizer memory scales down with the full mesh. Pure function — pjit places
the update wherever the shardings say.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(F32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(param_specs):
    """ShapeDtypeStruct state tree from abstract params (dry-run path)."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, F32)
    return {
        "m": jax.tree.map(f32, param_specs),
        "v": jax.tree.map(f32, param_specs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply(params, grads, state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** (step.astype(F32) + 1))
        vhat = v / (1 - cfg.b2 ** (step.astype(F32) + 1))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    new_state = {
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
        "step": step + 1,
    }
    return (jax.tree.unflatten(tdef, new_p), new_state,
            {"lr": lr, "grad_norm": gnorm})
